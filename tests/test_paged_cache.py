"""Paged KV cache correctness (DESIGN.md §12).

Two layers of oracle:

  * decode-level — a paged cache whose table maps each slot to its own
    page chain is BITWISE identical to the contiguous layout it replaces,
    for all four variants (GqaCache / QuantGqaCache / MlaCache /
    QuantMlaCache), because the gather ``pool[table]`` reconstructs the
    contiguous row in the same lane order and masked lanes contribute an
    exact softmax 0.0;
  * engine-level — the paged continuous engine (page faults, COW, prefix
    reuse, LIFO preemption under pool pressure) serves every request of a
    mixed trace bit-identically to the batch=1 wave oracle, for dense and
    NmCompressed-resident params, and ``snapshot()/restore()`` round-trips
    the page table mid-flight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import PruneConfig, prune_model
from repro.data.pipeline import calibration_batches
from repro.models import attention as A
from repro.models.model_builder import ModelAdapter, build_model
from repro.serve import Request, ServeConfig, ServingEngine
from repro.serve.compressed import compress_params

TINY = ModelConfig(
    name="paged-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
    vocab_size=96, dtype="float32")

MLA_TINY = ModelConfig(
    name="paged-mla-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
    vocab_size=96, dtype="float32",
    q_lora_rank=16, kv_lora_rank=16,
    qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=16)

MAX_LEN = 32
PAGE = 8
PPS = MAX_LEN // PAGE


def make_trace(seed: int, n: int, vocab: int) -> list[dict]:
    rng = np.random.default_rng(seed)
    return [{"uid": uid,
             "prompt": rng.integers(
                 0, vocab, size=int(rng.integers(3, 10))).astype(np.int32),
             "max_new": int(rng.integers(1, 7))}
            for uid in range(n)]


def serve_alone(model, params, spec: dict) -> list[int]:
    """Batch=1 wave oracle on the contiguous layout."""
    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=1, max_len=MAX_LEN,
                                    scheduler="wave"))
    eng.submit(Request(spec["uid"], spec["prompt"], max_new=spec["max_new"]))
    (req,) = eng.run()
    return req.out


def serve_paged(model, params, trace, *, slots: int, num_pages: int = 0,
                prefix_reuse: bool = True):
    eng = ServingEngine(
        model, params,
        ServeConfig(batch_slots=slots, max_len=MAX_LEN, paged=True,
                    page_size=PAGE, num_pages=num_pages,
                    prefix_reuse=prefix_reuse))
    for spec in trace:
        eng.submit(Request(spec["uid"], spec["prompt"],
                           max_new=spec["max_new"]))
    outs = {r.uid: r.out for r in eng.run()}
    return outs, eng


@pytest.fixture(scope="module")
def setup():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    batches = calibration_batches(TINY, num_samples=4, seq_len=8, batch=2)
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="magnitude", pattern="nm", n=2, m=4))
    comp = compress_params(pruned, report.masks, 2, 4)
    return model, params, comp


@pytest.fixture(scope="module")
def trace():
    return make_trace(seed=7, n=8, vocab=TINY.vocab_size)


@pytest.fixture(scope="module")
def oracle(setup, trace):
    model, params, comp = setup
    return {
        "dense": {s["uid"]: serve_alone(model, params, s) for s in trace},
        "comp": {s["uid"]: serve_alone(model, comp, s) for s in trace},
    }


# --------------------------------------------------------------------------
# decode-level: paged layout == contiguous layout, bitwise
# --------------------------------------------------------------------------
def _private_table(B: int) -> jnp.ndarray:
    """Every slot owns its own page chain: table[b, p] = 1 + b·P + p."""
    return (1 + jnp.arange(B * PPS, dtype=jnp.int32)).reshape(B, PPS)


@pytest.mark.parametrize("kv_dtype", ["", "int8"])
def test_gqa_paged_decode_matches_contiguous(kv_dtype):
    cfg = TINY.replace(kv_cache_dtype=kv_dtype) if kv_dtype else TINY
    B, d = 3, cfg.d_model
    params = A.gqa_params(jax.random.PRNGKey(1), cfg)
    cont = A.gqa_cache_init(cfg, B, MAX_LEN)
    paged = A.gqa_paged_cache_init(
        cfg, B, num_pages=1 + B * PPS, page_size=PAGE, pages_per_slot=PPS)
    paged = paged._replace(table=_private_table(B))
    rng = np.random.default_rng(1)
    for t in range(2 * PAGE + 3):              # crosses two page boundaries
        x = jnp.asarray(rng.normal(size=(B, 1, d)), jnp.float32)
        y_c, cont = A.gqa_decode(params, cfg, x, t, cont, theta=10000.0)
        y_p, paged = A.gqa_decode(params, cfg, x, t, paged, theta=10000.0)
        np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_p))


@pytest.mark.parametrize("kv_dtype", ["", "int8"])
def test_mla_paged_decode_matches_contiguous(kv_dtype):
    cfg = (MLA_TINY.replace(kv_cache_dtype=kv_dtype) if kv_dtype
           else MLA_TINY)
    B, d = 2, cfg.d_model
    params = A.mla_params(jax.random.PRNGKey(1), cfg)
    cont = A.mla_cache_init(cfg, B, MAX_LEN)
    paged = A.mla_paged_cache_init(
        cfg, B, num_pages=1 + B * PPS, page_size=PAGE, pages_per_slot=PPS)
    paged = paged._replace(table=_private_table(B))
    rng = np.random.default_rng(1)
    for t in range(PAGE + 3):
        x = jnp.asarray(rng.normal(size=(B, 1, d)), jnp.float32)
        y_c, cont = A.mla_decode(params, cfg, x, t, cont)
        y_p, paged = A.mla_decode(params, cfg, x, t, paged)
        np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_p))


# --------------------------------------------------------------------------
# engine-level: paged trace == batch=1 oracle
# --------------------------------------------------------------------------
def test_paged_trace_matches_batch1_dense(setup, trace, oracle):
    model, params, _ = setup
    outs, eng = serve_paged(model, params, trace, slots=3)
    assert outs == oracle["dense"]
    assert eng.stats["page_faults"] > 0


def test_paged_trace_matches_batch1_compressed_resident(setup, trace, oracle):
    from repro.core.sparsity import NmCompressed

    model, _, comp = setup
    leaves = [l for l in jax.tree.leaves(
        comp, is_leaf=lambda x: isinstance(x, NmCompressed))
        if isinstance(l, NmCompressed)]
    assert leaves, "fixture must be compressed-resident"
    outs, _ = serve_paged(model, comp, trace, slots=3)
    assert outs == oracle["comp"]


def test_paged_constrained_pool_preempts_and_stays_exact(setup, trace,
                                                         oracle):
    """A pool too small for full residency (LIFO preempt + resume on every
    collision) still reproduces the batch=1 outputs bit-for-bit."""
    model, params, _ = setup
    # 3 slots want 1 + 3·4 = 13 pages; 5 is the progress floor (1 + PPS)
    outs, eng = serve_paged(model, params, trace, slots=3, num_pages=5,
                            prefix_reuse=False)
    assert outs == oracle["dense"]
    assert eng.stats["preemptions"] > 0, "pool must actually be contended"
    eng.pager.check()


def test_paged_trace_exceeds_contiguous_capacity(setup, trace, oracle):
    """The headline capacity claim: total trace context exceeds the
    contiguous ``batch_slots × max_len`` worst-case allocation, yet the
    paged engine serves it exactly (memory scales with resident tokens)."""
    model, params, _ = setup
    slots = 2
    total_context = sum(len(s["prompt"]) + s["max_new"] for s in trace)
    assert total_context > slots * MAX_LEN
    outs, _ = serve_paged(model, params, trace, slots=slots)
    assert outs == oracle["dense"]


def test_paged_sliding_window_mixed_layout(trace):
    """Windowed layers keep contiguous rings (paging is pointless at O(W));
    full-attention layers page.  The mix still matches batch=1."""
    cfg = TINY.replace(sliding_window=6)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    expect = {s["uid"]: serve_alone(model, params, s) for s in trace}
    outs, eng = serve_paged(model, params, trace, slots=3)
    assert outs == expect
    assert eng.pager.prefix is None, \
        "prefix reuse must auto-disable for windowed models"


# --------------------------------------------------------------------------
# prefix reuse + copy-on-write
# --------------------------------------------------------------------------
def test_prefix_reuse_hits_and_stays_exact(setup, oracle):
    """A repeated prompt skips its prefill via shared pages; output is
    still the batch=1 answer (divergence handled by COW)."""
    model, params, _ = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, TINY.vocab_size, size=9).astype(np.int32)
    spec = {"uid": 0, "prompt": prompt, "max_new": 5}
    want = serve_alone(model, params, spec)

    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=2, max_len=MAX_LEN,
                                    paged=True, page_size=PAGE))
    eng.submit(Request(0, prompt, max_new=5))
    eng.run()
    eng.submit(Request(1, prompt, max_new=5))
    (req,) = eng.run()
    assert req.out == want
    assert eng.stats["prefix_hit_tokens"] > 0
    assert eng.stats["cow_copies"] > 0       # shared partial page diverges
    eng.pager.check()


def test_prefix_partial_match_merges_divergent_page(setup):
    """Two prompts sharing a full page + part of the next: the sharer keeps
    the full page, merges the partial one at admission, and both requests
    match their own batch=1 oracle."""
    model, params, _ = setup
    rng = np.random.default_rng(4)
    head = rng.integers(0, TINY.vocab_size, size=PAGE + 3)
    a = np.concatenate([head, [1, 2]]).astype(np.int32)
    b = np.concatenate([head, [3, 4]]).astype(np.int32)   # diverges in-page
    spec_a = {"uid": 0, "prompt": a, "max_new": 4}
    spec_b = {"uid": 1, "prompt": b, "max_new": 4}
    want = {0: serve_alone(model, params, spec_a),
            1: serve_alone(model, params, spec_b)}

    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=1, max_len=MAX_LEN,
                                    paged=True, page_size=PAGE))
    eng.submit(Request(0, a, max_new=4))
    eng.submit(Request(1, b, max_new=4))
    outs = {r.uid: r.out for r in eng.run()}
    assert outs == want
    assert eng.stats["prefix_hit_tokens"] >= PAGE
    eng.pager.check()


# --------------------------------------------------------------------------
# snapshot / restore round-trips the page table
# --------------------------------------------------------------------------
def test_paged_snapshot_restore_bit_identical(setup, trace, oracle):
    model, params, _ = setup
    cfg = ServeConfig(batch_slots=2, max_len=MAX_LEN, paged=True,
                      page_size=PAGE)
    eng = ServingEngine(model, params, cfg)
    for s in trace:
        eng.submit(Request(s["uid"], s["prompt"], max_new=s["max_new"]))
    for _ in range(4):
        assert eng.pump()
    snap = eng.snapshot()
    assert any(r is not None for r in snap["slots"])   # truly mid-flight
    snap["device"] = jax.tree.map(lambda l: np.asarray(l), snap["device"])

    eng2 = ServingEngine(model, params, cfg)
    eng2.restore(snap)
    outs = {r.uid: r.out for r in eng2.run()}
    assert outs == oracle["dense"]
    eng2.pager.check()


def test_paged_restore_rejects_layout_mismatch(setup):
    model, params, _ = setup
    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=2, max_len=MAX_LEN,
                                    paged=True, page_size=PAGE))
    snap = eng.snapshot()
    plain = ServingEngine(model, params,
                          ServeConfig(batch_slots=2, max_len=MAX_LEN))
    with pytest.raises(ValueError):
        plain.restore(snap)
    other = ServingEngine(model, params,
                          ServeConfig(batch_slots=2, max_len=MAX_LEN,
                                      paged=True, page_size=PAGE * 2))
    with pytest.raises(ValueError):
        other.restore(snap)


# --------------------------------------------------------------------------
# config validation
# --------------------------------------------------------------------------
def test_paged_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(paged=True, scheduler="wave")
    with pytest.raises(ValueError):
        ServeConfig(paged=True, max_len=30, page_size=16)   # not divisible
    with pytest.raises(ValueError):
        ServeConfig(batch_slots=2, max_len=32, paged=True, page_size=16,
                    num_pages=2)                 # below 1 + pages_per_slot
