"""Integration: the paper's Alg. 3 over whole models (deliverable b/c).

End-to-end: calibrate → block-wise prune → held-out loss ordering; plus
n:m compression round-trip through the serving path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import PruneConfig, prune_model
from repro.core.masks import check_nm
from repro.data.pipeline import calibration_batches, heldout_loss
from repro.models.model_builder import ModelAdapter, build_model
from repro.serve.compressed import (
    compress_params, compressed_bytes, decompress_params,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batches = calibration_batches(cfg, num_samples=16, seq_len=64, batch=8)
    return cfg, model, params, batches


@pytest.fixture(scope="module")
def tiny_trained():
    """Reduced tinyllama *briefly trained* on the synthetic corpus.

    Quality-ordering comparisons need a model whose function is worth
    preserving: at random init, held-out CE of the dense model is *worse*
    than a zero-regularized one (uniform-ward pruning helps), so
    magnitude-vs-data-aware orderings were a coin flip (the old seed
    flake).  ~60 steps puts dense CE well below the magnitude-pruned
    model's reachable region and the ordering becomes robust.
    """
    from repro.data.pipeline import SyntheticCorpus, TrainStream
    from repro.optim import AdamW
    from repro.train.step import make_train_step

    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW()
    step = make_train_step(model, opt, lambda s: 3e-3, donate=False)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    stream = TrainStream(corpus, global_batch=8, seq_len=64, num_hosts=1,
                         host_id=0, seed=11)
    state = opt.init(params)
    for i in range(60):
        params, state, _ = step(params, state, stream.batch_at(i))
    batches = calibration_batches(cfg, num_samples=32, seq_len=64, batch=8)
    return cfg, model, params, batches


@pytest.mark.slow
def test_blockwise_prune_sparsity_and_quality(tiny_trained):
    cfg, model, params, batches = tiny_trained
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", p=0.5, block_size=32),
    )
    assert abs(report.mean_sparsity() - 0.5) < 0.01
    dense = heldout_loss(model, params, cfg, num_batches=4, seq_len=64)
    sp = heldout_loss(model, pruned, cfg, num_batches=4, seq_len=64)
    assert np.isfinite(sp)
    # magnitude at the same sparsity must be worse (data-aware wins)
    mag, _ = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="magnitude", p=0.5),
    )
    mg = heldout_loss(model, mag, cfg, num_batches=4, seq_len=64)
    assert sp < mg
    # pruning a (briefly) trained model must cost, not gain, held-out CE —
    # a sizable 'improvement' over dense would signal an eval bug
    assert sp >= dense - 0.05


def test_nm_prune_then_compress_serve(tiny):
    cfg, model, params, batches = tiny
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", pattern="nm", n=2, m=4, block_size=64),
    )
    # every pruned layer satisfies 2:4 (mask stored (in, out) → transpose)
    for path, mask in report.masks.items():
        assert bool(check_nm(jnp.asarray(mask).T, 2, 4)), path

    comp = compress_params(pruned, report.masks, 2, 4)
    cbytes, dbytes = compressed_bytes(comp)
    assert cbytes < 0.70 * dbytes          # ~0.625 for bf16/fp32 mix

    # decompression reproduces the pruned params exactly
    restored = decompress_params(comp)
    flat_a = jax.tree_util.tree_leaves_with_path(pruned)
    restored_map = {tuple(str(k) for k in kp): l
                    for kp, l in jax.tree_util.tree_leaves_with_path(restored)}
    for kp, leaf in flat_a:
        key = tuple(str(k) for k in kp)
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(restored_map[key]))


@pytest.mark.slow
def test_moe_per_expert_hessians():
    """Expert slices are pruned with their own routed-token statistics."""
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batches = calibration_batches(cfg, num_samples=8, seq_len=32, batch=8)
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", p=0.5, block_size=16),
    )
    expert_paths = [p for p in report.masks if isinstance(p[-1], int)]
    assert expert_paths, "expert slices must be pruned individually"
    assert abs(report.mean_sparsity() - 0.5) < 0.02


@pytest.mark.slow
def test_shared_block_pruned_once():
    """Zamba2 shared attention weights appear exactly once in the masks."""
    cfg = get_config("zamba2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batches = calibration_batches(cfg, num_samples=8, seq_len=32, batch=8)
    _, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="wanda", p=0.5),
    )
    shared = [p for p in report.masks if p and p[0] == "shared"]
    assert len(shared) == len(set(shared))
    assert shared, "shared block linears must be pruned"
