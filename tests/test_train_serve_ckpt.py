"""Integration: trainer fault tolerance, serving engine, checkpointer,
sparse finetuning, straggler watchdog."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager, latest_step, load_checkpoint, save_checkpoint,
)
from repro.configs.base import ShapeCell
from repro.configs.registry import get_config
from repro.core import PruneConfig, prune_model
from repro.data.pipeline import SyntheticCorpus, TrainStream, calibration_batches
from repro.models.model_builder import ModelAdapter, build_model
from repro.optim import AdamW, sparsity_preserving
from repro.optim.schedules import cosine_warmup, linear_warmup
from repro.serve import Request, ServeConfig, ServingEngine
from repro.train import Trainer, TrainerConfig
from repro.train.trainer import StragglerWatchdog


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    return cfg, model


# ------------------------------------------------------------- checkpointer
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": {"w": jnp.arange(131072, dtype=jnp.float32).reshape(256, 512)},
        "b": {"x": jnp.ones((7,), jnp.bfloat16),
              "blocks": {0: {"k": jnp.zeros((3, 3))},
                         1: {"k": jnp.ones((3, 3))}}},
    }
    save_checkpoint(str(tmp_path), 42, tree, num_shards=3)
    step, back = load_checkpoint(str(tmp_path))
    assert step == 42
    assert back["b"]["x"].dtype == jnp.bfloat16
    assert set(back["b"]["blocks"].keys()) == {0, 1}   # int keys restored
    np.testing.assert_array_equal(np.asarray(back["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))


def test_checkpoint_atomic_and_retention(tmp_path):
    tree = {"w": jnp.ones((8, 8))}
    for s in (10, 20, 30, 40):
        save_checkpoint(str(tmp_path), s, tree, keep_last=2)
    assert latest_step(str(tmp_path)) == 40
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [30, 40]
    # a stale .tmp dir must be ignored by restore
    os.makedirs(tmp_path / "step_00000099.tmp", exist_ok=True)
    assert latest_step(str(tmp_path)) == 40


@pytest.mark.slow
def test_trainer_restart_exact(tmp_path, tiny):
    """Kill/restart reproduces the uninterrupted run exactly (counter-based
    data + checkpointed optimizer ⇒ bit-identical trajectory)."""
    cfg, model = tiny
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size)

    def make(total, d):
        stream = TrainStream(corpus, global_batch=4, seq_len=32)
        return Trainer(
            model, AdamW(weight_decay=0.0, clip_norm=0.0),
            linear_warmup(1e-3, 2, 8), stream,      # same horizon either way
            TrainerConfig(total_steps=total, ckpt_dir=str(d), save_every=4,
                          log_every=100, remat="none"),
        )

    t_full = make(8, tmp_path / "full")
    p_full, _ = t_full.run(jax.random.PRNGKey(0))

    t_a = make(4, tmp_path / "resume")
    t_a.run(jax.random.PRNGKey(0))
    t_b = make(8, tmp_path / "resume")          # resumes from step 4
    p_res, _ = t_b.run(jax.random.PRNGKey(0))

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=3.0, beta=0.5, warmup=3)
    for _ in range(6):
        assert not wd.observe(0.10)
    assert wd.observe(0.45)          # 4.5× EWMA → flagged
    assert wd.flagged == 1
    # EWMA not poisoned by the straggler
    assert wd.ewma < 0.12
    assert not wd.observe(0.11)


# ---------------------------------------------------------------- serving
def test_serving_engine_greedy_parity(tiny):
    """Engine greedy output == manual decode chain (wave batching exact)."""
    cfg, model = tiny
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6)

    eng = ServingEngine(model, params, ServeConfig(batch_slots=2, max_len=32))
    eng.submit(Request(0, prompt, max_new=4))
    out = eng.run()[0].out

    # manual: prefill token-by-token then greedy decode
    cache = model.init_cache(1, 32)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    for t in range(6):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
    manual = []
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    manual.append(int(cur[0, 0]))
    for t in range(3):
        logits, cache = model.decode_step(params, cache, cur, 6 + t)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        manual.append(int(cur[0, 0]))
    assert out == manual


@pytest.mark.slow
def test_serving_compressed_weights_identical(tiny):
    """n:m-compressed params serve the exact same greedy tokens as the
    dense pruned params (paper §4.8 — compression is lossless)."""
    cfg, model = tiny
    params = model.init(jax.random.PRNGKey(0))
    batches = calibration_batches(cfg, num_samples=8, seq_len=32, batch=8)
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", pattern="nm", n=2, m=4, block_size=32),
    )
    from repro.serve.compressed import compress_params

    comp = compress_params(pruned, report.masks, 2, 4)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=5)

    outs = []
    for p in (pruned, comp):
        eng = ServingEngine(model, p, ServeConfig(batch_slots=2, max_len=24))
        eng.submit(Request(0, prompt, max_new=4))
        outs.append(eng.run()[0].out)
    assert outs[0] == outs[1]


# --------------------------------------------------------- sparse finetune
@pytest.mark.slow
def test_sparse_finetune_preserves_mask(tiny):
    cfg, model = tiny
    params = model.init(jax.random.PRNGKey(0))
    batches = calibration_batches(cfg, num_samples=8, seq_len=32, batch=8)
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", p=0.5, block_size=32),
    )
    opt = sparsity_preserving(AdamW(weight_decay=0.1, clip_norm=1.0),
                              report.masks)
    state = opt.init(pruned)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size)
    stream = TrainStream(corpus, global_batch=4, seq_len=32)

    def loss_fn(p, b):
        return model.loss(p, b)

    p_cur = pruned
    for step in range(3):
        grads = jax.grad(loss_fn)(p_cur, stream.batch_at(step))
        p_cur, state = opt.update(grads, state, p_cur, jnp.asarray(1e-3))

    # every pruned coordinate is still exactly zero
    from repro.core.schedule import get_path
    for path, mask in report.masks.items():
        if isinstance(path[-1], int):
            kernel = get_path(p_cur, path[:-1])[path[-1]]
        else:
            kernel = get_path(p_cur, path)
        assert np.all(np.asarray(kernel)[np.asarray(mask) > 0.5] == 0.0)
