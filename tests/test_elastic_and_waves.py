"""Elastic-scaling restore + serving wave edge cases."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.registry import get_config
from repro.models.model_builder import build_model
from repro.serve import Request, ServeConfig, ServingEngine


def test_elastic_restore_across_shard_counts(tmp_path):
    """A checkpoint written with N shards restores identically with any
    manifest — the elastic-scaling contract (mesh/host count may change
    between save and restore)."""
    rng = np.random.default_rng(0)
    tree = {"blocks": {i: {"w": jnp.asarray(rng.normal(size=(64, 128)),
                                            jnp.float32)}
                       for i in range(4)},
            "norm": {"scale": jnp.ones((128,), jnp.bfloat16)}}
    for shards in (1, 2, 8):
        d = tmp_path / f"s{shards}"
        save_checkpoint(str(d), 7, tree, num_shards=shards,
                        shard_threshold=1024)
        step, back = load_checkpoint(str(d))
        assert step == 7
        for i in range(4):
            np.testing.assert_array_equal(
                np.asarray(back["blocks"][i]["w"]),
                np.asarray(tree["blocks"][i]["w"]))
        assert back["norm"]["scale"].dtype == jnp.bfloat16


def test_checkpoint_then_reshard_onto_mesh(tmp_path):
    """Restore returns logical arrays; re-sharding onto a (degenerate)
    mesh via dist.shard_params works on the restored tree."""
    from jax.sharding import Mesh

    from repro.dist.sharding import shard_params

    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, params)
    _, restored = load_checkpoint(str(tmp_path))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with mesh:
        sharded = shard_params(restored, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(sharded)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


@pytest.mark.parametrize("scheduler", ["wave", "continuous"])
def test_batching_mixed_lengths_and_overflow(scheduler):
    """Requests with different prompt lengths (wave: separate waves;
    continuous: packed per slot) and more requests than slots all complete
    with per-request outputs."""
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=2, max_len=32,
                                    scheduler=scheduler))
    rng = np.random.default_rng(0)
    lens = [4, 4, 4, 6, 6, 4]          # 2 waves of len-4 + 1 wave of len-6
    for uid, n in enumerate(lens):
        eng.submit(Request(uid, rng.integers(0, cfg.vocab_size, size=n),
                           max_new=3))
    done = eng.run()
    assert [r.uid for r in done] == list(range(6))
    assert all(len(r.out) == 3 and r.done for r in done)


@pytest.mark.parametrize("scheduler", ["wave", "continuous"])
def test_determinism_independent_of_submission_order(scheduler):
    """Greedy output for a request depends only on its prompt, not on
    queue position (scheduling-independence correctness)."""
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=5) for _ in range(3)]

    def serve(order):
        eng = ServingEngine(model, params,
                            ServeConfig(batch_slots=2, max_len=24,
                                        scheduler=scheduler))
        for uid in order:
            eng.submit(Request(uid, prompts[uid], max_new=4))
        return {r.uid: r.out for r in eng.run()}

    a = serve([0, 1, 2])
    b = serve([2, 0, 1])
    assert a == b
