"""End-to-end driver: train a ~small LM for a few hundred steps, prune it
with every method, and compare held-out quality — the full Alg.-3 pipeline
(deliverable b's end-to-end example).

    PYTHONPATH=src python examples/prune_and_eval.py [--steps 200]
"""
import argparse

import jax

from repro.configs.registry import get_config
from repro.core import PruneConfig, prune_model
from repro.data.pipeline import (
    SyntheticCorpus, TrainStream, calibration_batches, heldout_loss,
)
from repro.models.model_builder import ModelAdapter, build_model
from repro.optim import AdamW
from repro.optim.schedules import cosine_warmup
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)

    # ---- 1. train briefly so pruning has structure to preserve ----------
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size)
    stream = TrainStream(corpus, global_batch=8, seq_len=128)
    trainer = Trainer(
        model, AdamW(weight_decay=0.05, clip_norm=1.0),
        cosine_warmup(2e-3, args.steps // 10, args.steps), stream,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      save_every=100, log_every=50, remat="none"),
    )
    params, _ = trainer.run(jax.random.PRNGKey(0), log=print)
    dense = heldout_loss(model, params, cfg)
    print(f"\ndense held-out CE: {dense:.4f}")

    # ---- 2. calibrate + prune with every method --------------------------
    batches = calibration_batches(cfg, num_samples=32, seq_len=128, batch=8)
    adapter = ModelAdapter(model)
    for tag, cfgp in [
        ("thanos unstructured 50%", PruneConfig(method="thanos", p=0.5,
                                                block_size=64)),
        ("thanos 2:4 α=0.1", PruneConfig(method="thanos", pattern="nm",
                                         n=2, m=4, alpha=0.1,
                                         block_size=64)),
        ("thanos structured 30% α=0.1",
         PruneConfig(method="thanos", pattern="structured", p=0.3,
                     alpha=0.1)),
        ("sparsegpt unstructured 50%",
         PruneConfig(method="sparsegpt", p=0.5, block_size=64)),
        ("wanda unstructured 50%", PruneConfig(method="wanda", p=0.5)),
        ("magnitude unstructured 50%", PruneConfig(method="magnitude",
                                                   p=0.5)),
    ]:
        pruned, report = prune_model(params, adapter, batches, cfgp)
        loss = heldout_loss(model, pruned, cfg)
        print(f"{tag:32s} sparsity={report.mean_sparsity():.3f} "
              f"CE={loss:.4f} (Δ{loss - dense:+.4f}) "
              f"[{report.seconds:.1f}s]")


if __name__ == "__main__":
    main()
