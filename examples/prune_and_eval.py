"""End-to-end driver: train a ~small LM for a few hundred steps, prune it
with every method, and compare held-out quality — the full Alg.-3 pipeline
(deliverable b's end-to-end example), expressed through the PrunePlan
recipe API (DESIGN.md §11).

Covers the three ways to drive ``prune_model``:

* ``PrunePlan.uniform(cfg)`` — the paper's one-cell-everywhere setting;
* a mixed recipe (2:4 MLPs for the compressed serve path, unstructured
  attention, first block dense) loaded from examples/recipes/;
* ``allocate_sparsity`` — per-layer p under a global budget from the
  Hessian-trace saliency stats (BESA-style non-uniform allocation).

    PYTHONPATH=src python examples/prune_and_eval.py [--steps 200]
"""
import argparse
import os

import jax

from repro.configs.registry import get_config
from repro.core import (
    PruneConfig, PrunePlan, PruneRule, collect_hessian_stats, prune_model,
)
from repro.data.pipeline import (
    SyntheticCorpus, TrainStream, calibration_batches, heldout_loss,
)
from repro.models.model_builder import ModelAdapter, build_model
from repro.optim import AdamW
from repro.optim.schedules import cosine_warmup
from repro.train import Trainer, TrainerConfig

RECIPES = os.path.join(os.path.dirname(__file__), "recipes")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)

    # ---- 1. train briefly so pruning has structure to preserve ----------
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size)
    stream = TrainStream(corpus, global_batch=8, seq_len=128)
    trainer = Trainer(
        model, AdamW(weight_decay=0.05, clip_norm=1.0),
        cosine_warmup(2e-3, args.steps // 10, args.steps), stream,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      save_every=100, log_every=50, remat="none"),
    )
    params, _ = trainer.run(jax.random.PRNGKey(0), log=print)
    dense = heldout_loss(model, params, cfg)
    print(f"\ndense held-out CE: {dense:.4f}")

    # ---- 2. calibrate + prune: uniform plans for every method ------------
    batches = calibration_batches(cfg, num_samples=32, seq_len=128, batch=8)
    adapter = ModelAdapter(model)
    plans = [
        ("thanos unstructured 50%",
         PrunePlan.uniform(PruneConfig(method="thanos", p=0.5,
                                       block_size=64))),
        ("thanos 2:4 α=0.1",
         PrunePlan.uniform(PruneConfig(method="thanos", pattern="nm",
                                       n=2, m=4, alpha=0.1, block_size=64))),
        ("thanos structured 30% α=0.1",
         PrunePlan.uniform(PruneConfig(method="thanos", pattern="structured",
                                       p=0.3, alpha=0.1))),
        ("sparsegpt unstructured 50%",
         PrunePlan.uniform(PruneConfig(method="sparsegpt", p=0.5,
                                       block_size=64))),
        ("wanda unstructured 50%",
         PrunePlan.uniform(PruneConfig(method="wanda", p=0.5))),
        ("magnitude unstructured 50%",
         PrunePlan.uniform(PruneConfig(method="magnitude", p=0.5))),
    ]

    # mixed recipe from version control: 2:4 MLPs + unstructured attention
    # + dense embeddings/head, with the first block kept dense on top
    mixed = PrunePlan.load(os.path.join(RECIPES, "mixed_2to4_serve.json"))
    mixed = PrunePlan(rules=(
        PruneRule(match="blocks/0/*", cfg=None, name="dense-first-block"),
        *mixed.rules,
    ))
    plans.append(("mixed recipe (2:4 mlp / unstr attn)", mixed))

    # BESA-style non-uniform allocation: same budget, per-layer p from the
    # Hessian-trace saliency of a dense calibration pass
    stats = collect_hessian_stats(params, adapter, batches)
    alloc = PrunePlan.uniform(
        PruneConfig(method="thanos", p=0.5, block_size=64)
    ).allocate_sparsity(stats, policy="hessian_trace", budget=0.5,
                        p_min=0.1, p_max=0.9)
    plans.append(("thanos trace-allocated Σp=0.5", alloc))

    for tag, plan in plans:
        pruned, report = prune_model(params, adapter, batches, plan)
        loss = heldout_loss(model, pruned, cfg)
        print(f"{tag:36s} sparsity={report.mean_sparsity():.3f} "
              f"CE={loss:.4f} (Δ{loss - dense:+.4f}) "
              f"[{report.seconds:.1f}s]")

    # per-rule attribution of the last (allocated) run
    print("\nper-rule rollup of the allocated run:")
    for row in report.rule_rollup():
        print(f"  rule {row['rule']:3d} {row['tag']:24s} "
              f"layers={row['layers']:3d} "
              f"sparsity={row['mean_sparsity']:.3f}")


if __name__ == "__main__":
    main()
