"""Prune → sparse finetune: recover quality under a frozen sparsity mask.

Thanos prunes to 2:4; the sparsity-preserving optimizer wrapper then
finetunes only surviving weights (pruned coordinates provably stay zero —
see tests/test_train_serve_ckpt.py), recovering part of the pruning gap.

    PYTHONPATH=src python examples/sparse_finetune.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import PruneConfig, prune_model
from repro.data.pipeline import (
    SyntheticCorpus, TrainStream, calibration_batches, heldout_loss,
)
from repro.models.model_builder import ModelAdapter, build_model
from repro.optim import AdamW, sparsity_preserving
from repro.optim.schedules import cosine_warmup
from repro.train.step import make_train_step


def main(pretrain_steps: int = 150, finetune_steps: int = 100):
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size)
    stream = TrainStream(corpus, global_batch=8, seq_len=128)

    # pretrain
    opt = AdamW(weight_decay=0.05, clip_norm=1.0)
    step = make_train_step(model, opt, cosine_warmup(2e-3, 10,
                                                     pretrain_steps),
                           remat="none", donate=False)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    for i in range(pretrain_steps):
        params, state, m = step(params, state, stream.batch_at(i))
    print(f"dense CE:        {heldout_loss(model, params, cfg):.4f}")

    # prune 2:4
    batches = calibration_batches(cfg, num_samples=32, seq_len=128, batch=8)
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", pattern="nm", n=2, m=4, block_size=64))
    print(f"pruned 2:4 CE:   {heldout_loss(model, pruned, cfg):.4f} "
          f"(sparsity {report.mean_sparsity():.3f})")

    # sparse finetune — masked optimizer keeps pruned coords at zero
    sopt = sparsity_preserving(AdamW(weight_decay=0.01, clip_norm=1.0),
                               report.masks)
    sstate = sopt.init(pruned)
    sched = cosine_warmup(5e-4, 10, finetune_steps)
    loss_grad = jax.jit(jax.value_and_grad(model.loss))
    cur = pruned
    for i in range(finetune_steps):
        _, grads = loss_grad(cur, stream.batch_at(1000 + i))
        cur, sstate = sopt.update(grads, sstate, cur,
                                  sched(jnp.asarray(i)))
    print(f"finetuned CE:    {heldout_loss(model, cur, cfg):.4f}")

    # verify the mask survived finetuning
    from repro.core.schedule import get_path
    import numpy as np
    for path, mask in list(report.masks.items())[:3]:
        kern = (get_path(cur, path[:-1])[path[-1]]
                if isinstance(path[-1], int) else get_path(cur, path))
        assert np.all(np.asarray(kern)[np.asarray(mask) > 0.5] == 0.0)
    print("mask preserved through finetuning ✓")


if __name__ == "__main__":
    main()
