"""Serve a mixed-recipe-pruned model with per-layer residency.

Demonstrates the paper-§4.8 serving path driven by a ``PrunePlan``
(DESIGN.md §11): a mixed recipe prunes MLPs 2:4 and attention
unstructured-0.5 while embeddings stay dense; ``compress_params(...,
plan=report.plan)`` packs only the 2:4 layers, so the engine holds a tree
that is NmCompressed for MLPs and plain dense kernels everywhere else.
Greedy outputs are bit-identical to the dense pruned model (compression is
lossless); the run round-trips through the report JSON artifact.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core import NmCompressed, PrunePlan, prune_model
from repro.data.pipeline import calibration_batches
from repro.models.model_builder import ModelAdapter, build_model
from repro.serve import Request, ServeConfig, ServingEngine
from repro.serve.compressed import compress_params, compressed_bytes

RECIPES = os.path.join(os.path.dirname(__file__), "recipes")


def main():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    plan = PrunePlan.load(os.path.join(RECIPES, "mixed_2to4_serve.json"))
    batches = calibration_batches(cfg, num_samples=16, seq_len=64, batch=8)
    pruned, report = prune_model(params, ModelAdapter(model), batches, plan)
    for row in report.rule_rollup():
        print(f"rule {row['rule']:3d} {str(row['match']):20s} "
              f"{row['tag']:20s} layers={row['layers']:3d} "
              f"sparsity={row['mean_sparsity']:.3f}")

    # the report JSON embeds the plan — the run is reproducible from it
    art = json.loads(report.to_json())
    assert PrunePlan.from_dict(art["plan"]) == plan

    packed = compress_params(pruned, report.masks, plan=report.plan)
    comp, dense = compressed_bytes(packed)
    n_comp = sum(isinstance(l, NmCompressed)
                 for l in jax.tree.leaves(
                     packed, is_leaf=lambda x: isinstance(x, NmCompressed)))
    print(f"compressed {n_comp} layers: {comp / 1e6:.2f} MB "
          f"({comp / dense:.3f} of their dense bytes); "
          f"attention/embeddings stay dense")

    rng = np.random.default_rng(0)
    outs = {}
    for tag, p in (("dense-pruned", pruned), ("mixed-compressed", packed)):
        engine = ServingEngine(model, p,
                               ServeConfig(batch_slots=4, max_len=48))
        for uid in range(6):
            engine.submit(Request(
                uid, rng.integers(0, cfg.vocab_size, size=12), max_new=8))
        rng = np.random.default_rng(0)   # same prompts for both
        t0 = time.perf_counter()
        done = engine.run()
        print(f"{tag}: {sum(len(r.out) for r in done)} tokens "
              f"in {time.perf_counter() - t0:.2f}s")
        outs[tag] = [r.out for r in done]
    assert outs["dense-pruned"] == outs["mixed-compressed"]
    print("greedy outputs identical ✓ (per-layer residency is lossless)")


if __name__ == "__main__":
    main()
