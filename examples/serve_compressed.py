"""Serve a Thanos-2:4-pruned model from the compressed representation.

Demonstrates the paper-§4.8 serving path: prune → pack (values + in-group
indices) → batched wave serving.  Greedy outputs are bit-identical to the
dense pruned model (compression is lossless); the HBM win is quantified by
``python -m benchmarks.nm_decode_roofline``.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core import PruneConfig, prune_model
from repro.data.pipeline import calibration_batches
from repro.models.model_builder import ModelAdapter, build_model
from repro.serve import Request, ServeConfig, ServingEngine
from repro.serve.compressed import compress_params, compressed_bytes


def main():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batches = calibration_batches(cfg, num_samples=16, seq_len=64, batch=8)
    pruned, report = prune_model(
        params, ModelAdapter(model), batches,
        PruneConfig(method="thanos", pattern="nm", n=2, m=4, block_size=64))
    packed = compress_params(pruned, report.masks, 2, 4)
    comp, dense = compressed_bytes(packed)
    print(f"compressed linears: {comp / 1e6:.2f} MB "
          f"({comp / dense:.3f} of dense)")

    rng = np.random.default_rng(0)
    outs = {}
    for tag, p in (("dense-pruned", pruned), ("compressed", packed)):
        engine = ServingEngine(model, p,
                               ServeConfig(batch_slots=4, max_len=48))
        for uid in range(6):
            engine.submit(Request(
                uid, rng.integers(0, cfg.vocab_size, size=12), max_new=8))
        rng = np.random.default_rng(0)   # same prompts for both
        t0 = time.perf_counter()
        done = engine.run()
        print(f"{tag}: {sum(len(r.out) for r in done)} tokens "
              f"in {time.perf_counter() - t0:.2f}s")
        outs[tag] = [r.out for r in done]
    assert outs["dense-pruned"] == outs["compressed"]
    print("greedy outputs identical ✓")


if __name__ == "__main__":
    main()
