"""Quickstart: prune one linear layer with Thanos in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import PruneConfig, prune_layer, reconstruction_error
from repro.core.hessian import HessianAccumulator

# a layer W (out=c, in=b) and some calibration activations X (tokens, b)
key = jax.random.PRNGKey(0)
c, b, tokens = 512, 1024, 4096
w = jax.random.normal(key, (c, b)) * 0.02
x = jax.random.normal(jax.random.fold_in(key, 1), (tokens, b))

# 1. accumulate the layer Hessian H = 2·XᵀX over calibration batches
acc = HessianAccumulator.init(b)
for chunk in jnp.split(x, 4):
    acc = acc.update(chunk)
h = acc.finalize(mean=False)

# 2. prune — Thanos block-wise unstructured at 50% (paper Alg. 1)
res = prune_layer(w, h, PruneConfig(method="thanos", p=0.5, block_size=128))
print(f"sparsity: {float(jnp.mean(res.mask)):.3f}")
print(f"reconstruction error ‖(Ŵ−W)X‖²: "
      f"{float(reconstruction_error(w, res.weights, h)):.4f}")

# 3. compare against the baselines on the same layer
for method in ("sparsegpt", "wanda", "magnitude"):
    r = prune_layer(w, h, PruneConfig(method=method, p=0.5, block_size=128))
    print(f"{method:10s} error: "
          f"{float(reconstruction_error(w, r.weights, h)):.4f}")

# 4. hardware-friendly 2:4 with outlier-row protection (paper §4.8 + §4.7.1)
r24 = prune_layer(w, h, PruneConfig(method="thanos", pattern="nm",
                                    n=2, m=4, alpha=0.1, block_size=512))
print(f"2:4 α=0.1 sparsity: {float(jnp.mean(r24.mask)):.3f} "
      f"error: {float(reconstruction_error(w, r24.weights, h)):.4f}")
