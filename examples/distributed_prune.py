"""Row-parallel distributed pruning (DESIGN.md §3): rows of W shard across
the mesh, the Hessian is replicated (psum'd over the data axis during
calibration in a multi-host run), and Thanos' per-row solves proceed with
no inter-row communication.

On this CPU container the mesh is degenerate (1 device) — the point is the
*API and sharding layout*, which is identical at 256 chips (launch/dryrun
exercises the real meshes).

    PYTHONPATH=src python examples/distributed_prune.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import (
    PruneConfig, PrunePlan, PruneRule, prune_layer, reconstruction_error,
)
from repro.core.hessian import HessianAccumulator
from repro.dist.prune import prune_layer_sharded


def main():
    rng = np.random.default_rng(0)
    c, b = 256, 512
    w = jnp.asarray(rng.normal(size=(c, b)), jnp.float32)

    # calibration Hessian accumulated in shards (per-host batches), then
    # combined — and psum'd across the data axis via the cross-replica
    # reduction hook (identity on this degenerate 1-device mesh)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shards = []
    for i in range(4):
        x = jnp.asarray(rng.normal(size=(512, b)), jnp.float32)
        shards.append(HessianAccumulator.init(b).update(x))
    acc = HessianAccumulator.combine(*shards).all_reduce(mesh, ("data",))
    h = acc.finalize(mean=False)
    cfgp = PruneConfig(method="thanos", pattern="nm", n=2, m=4,
                       block_size=128)

    # the sharded driver resolves its cell through a PrunePlan — the same
    # recipe object the model-level drivers consume (DESIGN.md §11)
    plan = PrunePlan(rules=(PruneRule(match="embed*", cfg=None),
                            PruneRule(match="blocks/*", cfg=cfgp)))
    res_sharded = prune_layer_sharded(w, h, plan, mesh,
                                      path=("blocks", 0, "mlp", "up", "w"))
    res_local = prune_layer(w, h, cfgp)

    err_s = float(reconstruction_error(w, res_sharded.weights, h))
    err_l = float(reconstruction_error(w, res_local.weights, h))
    print(f"sharded:  sparsity={float(jnp.mean(res_sharded.mask)):.3f} "
          f"err={err_s:.2f}")
    print(f"local:    sparsity={float(jnp.mean(res_local.mask)):.3f} "
          f"err={err_l:.2f}")
    assert np.array_equal(np.asarray(res_sharded.mask),
                          np.asarray(res_local.mask))
    print("sharded ≡ local ✓ (row-parallel pruning is exact)")


if __name__ == "__main__":
    main()
